"""In-repo optimizers (no external deps): Adam, row-wise Adagrad, SGD.

Row-wise Adagrad is the production embedding optimizer (one accumulator
scalar per table ROW instead of per element — 1/D the state, the TorchRec
default for huge tables); Adam handles the dense parameters. ``make_mixed``
routes by parameter path, which is exactly how DLRM deployments configure it.

Row-wise Adagrad additionally takes **sparse row gradients**: a grads leaf
may be a ``repro.embeddings.sparse.SparseRows`` (COO, from
``make_sparse_value_and_grad``), in which case duplicates are segment-sum
merged and only the touched rows of the accumulator and the table are read
and written — per-row arithmetic is bit-identical to the dense apply
(tests/test_embeddings.py asserts exact equality), untouched rows never
move through memory.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.embeddings.sparse import SparseRows, is_sparse


class Optimizer(NamedTuple):
    init: Callable
    update: Callable        # (grads, state, params) -> (new_params, new_state)


def adam(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         grad_clip: float = 0.0) -> Optimizer:
    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": zeros,
                "v": jax.tree.map(jnp.zeros_like, zeros),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        if grad_clip > 0:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(grads)) + 1e-12)
            scale = jnp.minimum(1.0, grad_clip / gn)
            grads = jax.tree.map(lambda g: g * scale, grads)
        t = state["t"] + 1
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + lr * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "t": t}

    return Optimizer(init, update)


def _rowwise_sparse_apply(p, g: SparseRows, a, lr: float, eps: float):
    """Touched-rows-only row-wise Adagrad step from a COO row gradient.

    Duplicate ids are merged first (dense scatter semantics: contributions
    add, THEN the row_sq/accumulator math runs — merging after would change
    the accumulator), then only the |touched| rows of ``a`` and ``p`` are
    gathered, stepped with the exact dense arithmetic, and scattered back.
    Padding entries (id == vocab) drop out of both scatters.
    """
    m = g.merged()
    ids = m.ids                                    # (N,) unique; vocab = pad
    g32 = m.rows.astype(jnp.float32)
    touched = ids < g.vocab
    safe = jnp.where(touched, ids, 0)
    row_sq = jnp.mean(g32 * g32, axis=tuple(range(1, g32.ndim)))
    a_rows = jnp.take(a, safe) + jnp.where(touched, row_sq, 0.0)
    scale = lr / (jnp.sqrt(a_rows) + eps)
    step = g32 * scale.reshape((-1,) + (1,) * (g32.ndim - 1))
    p_rows = (jnp.take(p, safe, axis=0).astype(jnp.float32)
              - step).astype(p.dtype)
    new_p = p.at[ids].set(p_rows, mode="drop")
    new_a = a.at[ids].set(a_rows, mode="drop")
    return new_p, new_a


def rowwise_adagrad(lr: float = 0.01, eps: float = 1e-8) -> Optimizer:
    """One accumulator per embedding row: state[p] has shape p.shape[:1].

    Dense grads update every row; :class:`SparseRows` grads scatter-update
    only the touched rows (identical per-row arithmetic)."""
    def init(params):
        return {"acc": jax.tree.map(
            lambda p: jnp.zeros(p.shape[:1], jnp.float32), params)}

    def update(grads, state, params):
        def upd(p, g, a):
            if is_sparse(g):
                return _rowwise_sparse_apply(p, g, a, lr, eps)
            g32 = g.astype(jnp.float32)
            row_sq = jnp.mean(g32 * g32, axis=tuple(range(1, g32.ndim)))
            a = a + row_sq
            scale = lr / (jnp.sqrt(a) + eps)
            step = g32 * scale.reshape((-1,) + (1,) * (g32.ndim - 1))
            return (p.astype(jnp.float32) - step).astype(p.dtype), a

        out = jax.tree.map(upd, params, grads, state["acc"])
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_a = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"acc": new_a}

    return Optimizer(init, update)


def sgd(lr: float = 0.1, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return {"mom": jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)}
        return {}

    def update(grads, state, params):
        if momentum:
            new_mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mom"], grads)
            new_p = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
                params, new_mom)
            return new_p, {"mom": new_mom}
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_p, {}

    return Optimizer(init, update)


def make_mixed(dense_opt: Optimizer, embedding_opt: Optimizer,
               is_embedding: Callable[[Tuple], bool]) -> Optimizer:
    """Route params by tree path: embedding tables -> embedding_opt,
    everything else -> dense_opt (the standard DLRM setup)."""

    def _mask(params):
        """Static (trace-time) embedding mask from tree paths."""
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        return [is_embedding(tuple(str(k) for k in path)) for path, _ in flat]

    def init(params):
        emb_mask = _mask(params)
        leaves = jax.tree.leaves(params)
        emb_leaves = [l for l, m in zip(leaves, emb_mask) if m]
        dense_leaves = [l for l, m in zip(leaves, emb_mask) if not m]
        return {
            "emb": embedding_opt.init(emb_leaves),
            "dense": dense_opt.init(dense_leaves),
        }

    def update(grads, state, params):
        emb_mask = _mask(params)
        # SparseRows grads are leaves here: they must stay whole and pair
        # up positionally with their table param
        g_leaves = jax.tree.leaves(grads, is_leaf=is_sparse)
        p_leaves = jax.tree.leaves(params)
        ge = [g for g, m in zip(g_leaves, emb_mask) if m]
        pe = [p for p, m in zip(p_leaves, emb_mask) if m]
        gd = [g for g, m in zip(g_leaves, emb_mask) if not m]
        pd = [p for p, m in zip(p_leaves, emb_mask) if not m]
        new_pe, new_se = embedding_opt.update(ge, state["emb"], pe)
        new_pd, new_sd = dense_opt.update(gd, state["dense"], pd)
        it_e, it_d = iter(new_pe), iter(new_pd)
        merged = [next(it_e) if m else next(it_d) for m in emb_mask]
        new_params = jax.tree.unflatten(jax.tree.structure(params), merged)
        return new_params, {"emb": new_se, "dense": new_sd}

    return Optimizer(init, update)


def default_is_embedding(path: Tuple[str, ...]) -> bool:
    s = "/".join(path).lower()
    return any(k in s for k in ("emb", "table"))
