"""Evaluation metrics: Normalized Entropy (NE) and Recall@K.

NE (He et al. 2014) = cross-entropy of the model / cross-entropy of the
background CTR predictor — the paper's ranking metric (lower is better;
NE < 1 beats predicting the base rate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bce(logits: jnp.ndarray, labels: jnp.ndarray,
        weights: jnp.ndarray | None = None) -> jnp.ndarray:
    l = jnp.maximum(logits, 0) - logits * labels + \
        jnp.log1p(jnp.exp(-jnp.abs(logits)))
    if weights is None:
        return jnp.mean(l)
    return jnp.sum(l * weights) / jnp.maximum(jnp.sum(weights), 1.0)


def normalized_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                       weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """NE = CE(model) / CE(base rate)."""
    if weights is None:
        weights = jnp.ones_like(labels)
    ce = bce(logits, labels, weights)
    p = jnp.sum(labels * weights) / jnp.maximum(jnp.sum(weights), 1.0)
    p = jnp.clip(p, 1e-6, 1 - 1e-6)
    ce_base = -(p * jnp.log(p) + (1 - p) * jnp.log(1 - p))
    return ce / ce_base


def make_ne_metrics(logits_labels_fn):
    """Build a Trainer ``metrics_fn`` surfacing NE in the step metrics.

    ``logits_labels_fn(params, batch) -> (logits, labels[, weights])``
    extracts the primary-task head from the model; the returned callable
    plugs into ``Trainer(metrics_fn=...)`` / ``make_train_step`` so every
    logged history row carries the paper's quality metric alongside loss.
    """
    def metrics_fn(params, batch, rng):
        out = logits_labels_fn(params, batch)
        logits, labels = out[0], out[1]
        weights = out[2] if len(out) > 2 else None
        return {"ne": normalized_entropy(logits, labels, weights)}
    return metrics_fn


def recall_at_k(user_repr: jnp.ndarray, item_repr: jnp.ndarray,
                positives: jnp.ndarray, k: int = 100) -> jnp.ndarray:
    """user_repr: (B, d); item_repr: (N, d); positives: (B,) item indices.
    Fraction of users whose positive lands in their top-k scores."""
    scores = user_repr @ item_repr.T                    # (B, N)
    pos_score = jnp.take_along_axis(scores, positives[:, None], axis=1)[:, 0]
    rank = jnp.sum(scores > pos_score[:, None], axis=1)
    return jnp.mean((rank < k).astype(jnp.float32))


def auc(logits: jnp.ndarray, labels: jnp.ndarray, n_bins: int = 1024):
    """Histogram-approximated ROC-AUC (streaming-friendly)."""
    p = jax.nn.sigmoid(logits)
    bins = jnp.clip((p * n_bins).astype(jnp.int32), 0, n_bins - 1)
    pos = jnp.bincount(bins, weights=labels, length=n_bins)
    neg = jnp.bincount(bins, weights=1 - labels, length=n_bins)
    cneg = jnp.cumsum(neg) - neg
    auc_num = jnp.sum(pos * (cneg + 0.5 * neg))
    return auc_num / jnp.maximum(jnp.sum(pos) * jnp.sum(neg), 1.0)
