"""Fault-tolerant checkpointing: atomic, async, keep-k, elastic reshard.

Designed for thousands-of-nodes operation:
  * atomic commit (write to tmp dir + rename) — a preempted writer never
    corrupts the latest checkpoint;
  * async save thread — training never blocks on storage;
  * keep-last-k retention;
  * resume picks the newest COMMITTED step; partial writes are ignored;
  * sharded state: a leaf laid out over >1 device is snapshotted per shard
    (``a{i}.s{k}`` entries in arrays.npz) plus a ``sharding.json`` manifest
    recording each leaf's global shape/dtype, PartitionSpec and shard
    index ranges — no host-side gather of the global array on save;
  * elastic reshard: restore reassembles global arrays from the shard
    entries, so a restore may target a *different* mesh/topology —
    ``restore_sharded(mesh)`` re-applies every saved spec onto the new mesh
    (axes that don't exist or don't divide fall back to replicated), and
    ``restore_resharded()`` takes an explicit shardings pytree
    (tested mesh A -> mesh B);
  * deterministic data skip: the step number keys the data iterator offset,
    so a restarted worker replays nothing and skips nothing.
"""
from __future__ import annotations

import io
import json
import os
import pickle
import shutil
import threading
import time
import zlib
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.reliability import faults


class CheckpointCorruptionError(ValueError):
    """An explicitly requested checkpoint step failed integrity checks."""


def _is_sharded(x) -> bool:
    return isinstance(x, jax.Array) and len(x.sharding.device_set) > 1


def _resolve_dtype(name: str) -> np.dtype:
    """Dtype from its string name, including ml_dtypes extension types
    (bfloat16, float8_*) that np.dtype alone can't parse."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _spec_to_json(sharding) -> Optional[list]:
    """PartitionSpec -> JSON ([axis | [axes...] | null, ...]); None when the
    leaf has no NamedSharding (spec unknown — restore replicates)."""
    spec = getattr(sharding, "spec", None)
    if spec is None:
        return None
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            out.append(list(e))
        else:
            out.append(str(e))
    return out


def _spec_from_json(entries: Optional[list], mesh) -> Optional[Any]:
    """JSON spec -> PartitionSpec valid on ``mesh`` (axes filtered to those
    the mesh actually has); None when nothing survives."""
    from jax.sharding import PartitionSpec as P
    if entries is None or mesh is None:
        return None
    names = set(mesh.axis_names)
    out = []
    for e in entries:
        if e is None:
            out.append(None)
        elif isinstance(e, list):
            kept = tuple(a for a in e if a in names)
            out.append(kept if kept else None)
        else:
            out.append(e if e in names else None)
    return P(*out)


def _snapshot_leaf(i: int, x) -> tuple:
    """Host snapshot of one state leaf.

    Returns (arrays: {npz_key: np.ndarray}, manifest_entry | None). Sharded
    leaves snapshot per device shard (deduped by index — replicated-axis
    copies are identical); everything else snapshots whole. ml_dtypes
    leaves always get a manifest entry (one full-extent shard), sharded or
    not, so their dtype survives npz.
    """
    if not _is_sharded(x):
        arr = np.asarray(x)
        if arr.dtype.kind != "V":
            return {f"a{i}": arr}, None
        # unsharded bfloat16/float8 leaf: route through the byte-view +
        # manifest path as a single shard covering the whole array
        key = f"a{i}.s0"
        entry = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                 "spec": None,
                 "shards": [{"key": key,
                             "index": [[0, d] for d in arr.shape]}]}
        if arr.ndim >= 1:
            arr = np.ascontiguousarray(arr).view(np.uint8)
        return {key: arr}, entry
    arrays: Dict[str, np.ndarray] = {}
    shards_meta: List[dict] = []
    seen = set()
    for shard in x.addressable_shards:
        index = tuple(
            (0 if sl.start is None else int(sl.start),
             dim if sl.stop is None else int(sl.stop))
            for sl, dim in zip(shard.index, x.shape))
        if index in seen:
            continue
        seen.add(index)
        key = f"a{i}.s{len(shards_meta)}"
        # plain asarray: ascontiguousarray would promote 0-d to (1,)
        arr = np.asarray(shard.data)
        if arr.dtype.kind == "V" and arr.ndim >= 1:
            # ml_dtypes (bfloat16, float8_*) degrade to raw void inside
            # npz; store the byte view — the manifest dtype restores it.
            # 0-d arrays can't change itemsize; their void bytes already
            # round-trip and restore() views them back by itemsize.
            arr = np.ascontiguousarray(arr).view(np.uint8)
        arrays[key] = arr
        shards_meta.append({"key": key, "index": [list(r) for r in index]})
    entry = {"shape": list(x.shape), "dtype": str(x.dtype),
             "spec": _spec_to_json(x.sharding), "shards": shards_meta}
    return arrays, entry


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3,
                 meta: Optional[Dict[str, Any]] = None):
        # ``meta``: extra provenance merged into every step's meta.json
        # (core keys — step/ts/digests — always win on collision)
        self.dir = directory
        self.keep_last = keep_last
        self.meta = dict(meta) if meta else {}
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        # a writer killed mid-save leaves step_*.tmp dirs; they were never
        # committed (all_steps ignores them) so they are pure dead weight
        self._sweep_tmp()

    def _sweep_tmp(self) -> None:
        for name in os.listdir(self.dir):
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # ---- save -----------------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:012d}")

    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        # snapshot to host memory synchronously (cheap), write async
        flat, treedef = jax.tree_util.tree_flatten(state)
        host: Dict[str, np.ndarray] = {}
        sharded_manifest: Dict[str, dict] = {}
        for i, x in enumerate(flat):
            arrays, entry = _snapshot_leaf(i, x)
            host.update(arrays)
            if entry is not None:
                sharded_manifest[str(i)] = entry

        def _write():
            tmp = self._path(step) + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            digests: Dict[str, int] = {}   # filename -> crc32 of bytes

            def put(name: str, blob: bytes) -> None:
                with open(os.path.join(tmp, name), "wb") as f:
                    f.write(blob)
                digests[name] = zlib.crc32(blob)

            buf = io.BytesIO()
            np.savez(buf, **host)
            put("arrays.npz", buf.getvalue())
            put("treedef.pkl", pickle.dumps(treedef))
            if sharded_manifest:
                put("sharding.json",
                    json.dumps(sharded_manifest).encode("utf-8"))
            spec = faults.fire("ckpt.write")
            if spec is not None and spec.kind == "torn":
                # simulated kill between payload write and commit: the
                # .tmp dir stays behind, meta.json is never written, and
                # all_steps() never reports this step
                return
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({**self.meta,
                           "step": step, "ts": time.time(),
                           "n_arrays": len(flat),
                           "n_sharded": len(sharded_manifest),
                           "digests": digests}, f)
            final = self._path(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic commit
            if spec is not None and spec.kind == "corrupt":
                # bit rot after commit: flip a byte in the committed
                # payload so only digest verification can catch it
                apath = os.path.join(final, "arrays.npz")
                with open(apath, "rb") as f:
                    blob = f.read()
                with open(apath, "wb") as f:
                    f.write(faults.corrupt_bytes("ckpt.write", blob, spec))
            self._gc()

        if blocking:
            _write()
        else:
            self.wait()
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self._path(s), ignore_errors=True)
        self._sweep_tmp()

    # ---- restore ----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "meta.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ---- integrity --------------------------------------------------------------
    def verify(self, step: int) -> bool:
        """True iff every payload file matches the crc32 digest recorded in
        the step's meta.json. Checkpoints written before digests existed
        have nothing to check against and are trusted."""
        path = self._path(step)
        try:
            with open(os.path.join(path, "meta.json")) as f:
                meta = json.load(f)
        except (OSError, ValueError):
            return False
        digests = meta.get("digests")
        if digests is None:
            return True                     # pre-digest checkpoint
        for name, want in digests.items():
            try:
                with open(os.path.join(path, name), "rb") as f:
                    got = zlib.crc32(f.read())
            except OSError:
                return False
            if got != int(want):
                return False
        return True

    def valid_steps(self) -> List[int]:
        return [s for s in self.all_steps() if self.verify(s)]

    def latest_valid_step(self) -> Optional[int]:
        """Newest step that passes integrity verification — the step
        ``restore()`` falls back to when the latest commit rotted."""
        for s in reversed(self.all_steps()):
            if self.verify(s):
                return s
        return None

    def _load_manifest(self, path: str) -> Dict[str, dict]:
        mpath = os.path.join(path, "sharding.json")
        if not os.path.exists(mpath):
            return {}
        with open(mpath) as f:
            return json.load(f)

    def restore(self, step: Optional[int] = None) -> Any:
        """Restore as host (global) arrays; shard entries are reassembled.

        With ``step=None`` restores the newest step that PASSES integrity
        verification (silently skipping corrupt/torn ones); an explicitly
        requested corrupt step raises :class:`CheckpointCorruptionError`.
        """
        if step is None:
            step = self.latest_valid_step()
            if step is None:
                raise FileNotFoundError(
                    f"no committed valid checkpoint in {self.dir}")
        elif not self.verify(step):
            raise CheckpointCorruptionError(
                f"checkpoint step {step} in {self.dir} failed integrity "
                f"verification (crc mismatch or missing payload)")
        path = self._path(step)
        with open(os.path.join(path, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        manifest = self._load_manifest(path)
        n = treedef.num_leaves
        flat = []
        for i in range(n):
            entry = manifest.get(str(i))
            if entry is None:
                flat.append(data[f"a{i}"])
                continue
            dtype = _resolve_dtype(entry["dtype"])
            out = np.empty(tuple(entry["shape"]), dtype=dtype)
            for sh in entry["shards"]:
                sl = tuple(slice(s, e) for s, e in sh["index"])
                block = data[sh["key"]]
                if dtype.kind == "V" and block.dtype != dtype:
                    block = block.view(dtype)   # byte / raw-void view back
                if sl:
                    out[sl] = block
                else:
                    out = block.reshape(())     # 0-d leaf: single shard
            flat.append(out)
        return jax.tree_util.tree_unflatten(treedef, flat)

    def saved_specs(self, step: Optional[int] = None) -> Dict[int, list]:
        """leaf index -> JSON PartitionSpec for sharded leaves of a step."""
        step = step if step is not None else self.latest_valid_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        manifest = self._load_manifest(self._path(step))
        return {int(i): e["spec"] for i, e in manifest.items()}

    def restore_sharded(self, mesh, step: Optional[int] = None) -> Any:
        """Restore onto ``mesh``, re-applying every leaf's saved
        PartitionSpec — the mesh may have a different shape (or different
        axes) than the one the checkpoint was saved from. Specs whose axes
        are missing from the new mesh, or don't divide the leaf, fall back
        to replicated placement.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P
        state = self.restore(step)
        specs = self.saved_specs(step)
        flat, treedef = jax.tree_util.tree_flatten(state)
        placed = []
        for i, x in enumerate(flat):
            spec = _spec_from_json(specs.get(i), mesh)
            if spec is None:
                placed.append(jax.device_put(
                    x, NamedSharding(mesh, P())))
                continue
            # divisibility check per dim against the NEW mesh
            ok = True
            for dim, e in zip(np.shape(x), tuple(spec)):
                if e is None:
                    continue
                axes = (e,) if isinstance(e, str) else tuple(e)
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                if n > 1 and dim % n != 0:
                    ok = False
            placed.append(jax.device_put(
                x, NamedSharding(mesh, spec if ok else P())))
        return jax.tree_util.tree_unflatten(treedef, placed)

    def restore_resharded(self, shardings: Any,
                          step: Optional[int] = None) -> Any:
        """Restore onto a (possibly different) mesh: `shardings` is a pytree
        of NamedSharding (or None) congruent with the saved state."""
        state = self.restore(step)

        def place(x, s):
            return jax.device_put(x, s) if s is not None else jax.device_put(x)

        return jax.tree.map(place, state, shardings)
