"""Fault-tolerant checkpointing: atomic, async, keep-k, elastic reshard.

Designed for thousands-of-nodes operation:
  * atomic commit (write to tmp dir + rename) — a preempted writer never
    corrupts the latest checkpoint;
  * async save thread — training never blocks on storage;
  * keep-last-k retention;
  * resume picks the newest COMMITTED step; partial writes are ignored;
  * elastic reshard: checkpoints store the global (unsharded) arrays, so a
    restore may target a different mesh/topology — restore_resharded()
    re-applies any sharding on load (tested mesh A -> mesh B);
  * deterministic data skip: the step number keys the data iterator offset,
    so a restarted worker replays nothing and skips nothing.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---- save -----------------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:012d}")

    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        # snapshot to host memory synchronously (cheap), write async
        flat, treedef = jax.tree_util.tree_flatten(state)
        host = [np.asarray(x) for x in flat]

        def _write():
            tmp = self._path(step) + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
                np.savez(f, **{f"a{i}": a for i, a in enumerate(host)})
            with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
                pickle.dump(treedef, f)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, "ts": time.time(),
                           "n_arrays": len(host)}, f)
            final = self._path(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic commit
            self._gc()

        if blocking:
            _write()
        else:
            self.wait()
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # ---- restore ----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "meta.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None) -> Any:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = self._path(step)
        with open(os.path.join(path, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = [data[f"a{i}"] for i in range(len(data.files))]
        return jax.tree_util.tree_unflatten(treedef, flat)

    def restore_resharded(self, shardings: Any,
                          step: Optional[int] = None) -> Any:
        """Restore onto a (possibly different) mesh: `shardings` is a pytree
        of NamedSharding (or None) congruent with the saved state."""
        state = self.restore(step)

        def place(x, s):
            return jax.device_put(x, s) if s is not None else jax.device_put(x)

        return jax.tree.map(place, state, shardings)
