"""Jagged (ragged) tensor substrate — the JAX analogue of TorchRec's
KeyedJaggedTensor.

XLA requires static shapes, so a JaggedTensor carries a *fixed-capacity*
`values` buffer plus `lengths`/`offsets` bookkeeping. Semantics (what the
paper stores in its request-level schema, Table 2) live in the indices; the
padding never leaks into model math because every consumer masks by length.

Two layouts are used throughout the framework:

  * ``JaggedTensor``    — one ragged axis: values ``(capacity, *feat)`` +
    ``lengths (batch,)``. Used for ID-list features, user histories, and the
    impressions-per-request structure of a ROO batch.
  * ``KeyedJagged``     — a dict of named JaggedTensors sharing a batch size
    (the KJT analogue), used by the embedding collection.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _cumsum_exclusive(lengths: jnp.ndarray) -> jnp.ndarray:
    return jnp.concatenate([jnp.zeros((1,), lengths.dtype), jnp.cumsum(lengths)[:-1]])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class JaggedTensor:
    """values[(capacity, *feat)] + lengths[(batch,)]; rows are contiguous.

    ``offsets[i] = sum(lengths[:i])`` gives the start of row i in `values`.
    Entries past ``sum(lengths)`` are padding and must be masked by consumers.
    """

    values: jnp.ndarray      # (capacity, ...) packed row-major by batch entry
    lengths: jnp.ndarray     # (batch,) int32

    def tree_flatten(self):
        return (self.values, self.lengths), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def batch_size(self) -> int:
        return self.lengths.shape[0]

    @property
    def capacity(self) -> int:
        return self.values.shape[0]

    @property
    def offsets(self) -> jnp.ndarray:
        return _cumsum_exclusive(self.lengths)

    def total(self) -> jnp.ndarray:
        return jnp.sum(self.lengths)

    # ---- index bookkeeping -------------------------------------------------
    def segment_ids(self) -> jnp.ndarray:
        """(capacity,) int32 mapping each value slot -> batch row.

        Padding slots get ``batch_size`` (one past the end) so that
        ``segment_sum(..., num_segments=batch_size)`` drops them and
        ``take(x, seg_ids, fill_value)``-style gathers can detect them.
        """
        # slot i belongs to row r iff offsets[r] <= i < offsets[r]+lengths[r]
        idx = jnp.arange(self.capacity, dtype=jnp.int32)
        # searchsorted over offsets+lengths boundaries
        ends = jnp.cumsum(self.lengths)
        seg = jnp.searchsorted(ends, idx, side="right").astype(jnp.int32)
        valid = idx < ends[-1] if self.batch_size > 0 else jnp.zeros_like(idx, bool)
        return jnp.where(valid, seg, self.batch_size)

    def valid_mask(self) -> jnp.ndarray:
        """(capacity,) bool — True for real entries, False for padding."""
        idx = jnp.arange(self.capacity, dtype=jnp.int32)
        return idx < self.total()

    # ---- densification -----------------------------------------------------
    def to_padded(self, max_len: int, fill_value=0) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Return (batch, max_len, *feat) dense tensor + (batch, max_len) mask.

        Rows longer than ``max_len`` are truncated.
        """
        b = self.batch_size
        offs = self.offsets
        pos = jnp.arange(max_len, dtype=jnp.int32)
        gather_idx = offs[:, None] + pos[None, :]                    # (b, max_len)
        mask = pos[None, :] < jnp.minimum(self.lengths, max_len)[:, None]
        gather_idx = jnp.clip(gather_idx, 0, self.capacity - 1)
        dense = jnp.take(self.values, gather_idx.reshape(-1), axis=0)
        dense = dense.reshape((b, max_len) + self.values.shape[1:])
        fill = jnp.asarray(fill_value, dense.dtype)
        bmask = mask.reshape(mask.shape + (1,) * (dense.ndim - 2))
        return jnp.where(bmask, dense, fill), mask

    @staticmethod
    def from_dense(dense: jnp.ndarray, lengths: jnp.ndarray,
                   capacity: int | None = None) -> "JaggedTensor":
        """Pack a padded (batch, max_len, *feat) tensor into jagged layout."""
        b, ml = dense.shape[0], dense.shape[1]
        capacity = capacity if capacity is not None else b * ml
        offs = _cumsum_exclusive(lengths)
        pos = jnp.arange(ml, dtype=jnp.int32)
        valid = pos[None, :] < lengths[:, None]
        # destination slot for each (row, pos)
        dest = offs[:, None] + pos[None, :]
        dest = jnp.where(valid, dest, capacity)  # park padding out of range
        flat_src = dense.reshape((b * ml,) + dense.shape[2:])
        out = jnp.zeros((capacity + 1,) + dense.shape[2:], dense.dtype)
        out = out.at[dest.reshape(-1)].set(flat_src, mode="drop")
        return JaggedTensor(out[:capacity], lengths.astype(jnp.int32))

    # ---- numpy-side construction (host data path) ---------------------------
    @staticmethod
    def from_lists(rows: Sequence[Sequence], capacity: int,
                   dtype=np.int32) -> "JaggedTensor":
        lengths = np.asarray([len(r) for r in rows], np.int32)
        flat = np.zeros((capacity,), dtype)
        cat = np.concatenate([np.asarray(r, dtype) for r in rows]) if rows else np.zeros((0,), dtype)
        n = min(capacity, cat.shape[0])
        flat[:n] = cat[:n]
        return JaggedTensor(jnp.asarray(flat), jnp.asarray(lengths))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class KeyedJagged:
    """Named bundle of JaggedTensors with a shared batch size (KJT analogue)."""

    features: Dict[str, JaggedTensor]

    def tree_flatten(self):
        keys = sorted(self.features)
        return tuple(self.features[k] for k in keys), tuple(keys)

    @classmethod
    def tree_unflatten(cls, keys, children):
        return cls(dict(zip(keys, children)))

    def __getitem__(self, key: str) -> JaggedTensor:
        return self.features[key]

    def keys(self):
        return sorted(self.features)

    @property
    def batch_size(self) -> int:
        any_key = next(iter(self.features))
        return self.features[any_key].batch_size
