"""ROO mini-batch packing (host side, numpy).

Packs a list of ROOSamples into fixed-shape ``ROOBatch`` pytrees:
  * ``B_RO`` request rows, ``B_NRO`` impression slots (static capacities);
  * requests are packed shard-by-shard so that, when the leading dims are
    sharded over N data shards, every request's impressions live on the same
    shard as the request row (the *request-locality* invariant fanout_local
    depends on);
  * ``segment_ids`` can be emitted global (default) or shard-local.

Also provides the impression-level packing used by baseline (non-ROO)
training and by the ROO-expansion backward-compat adapter.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.core.joiner import ImpressionSample, ROOSample
from repro.core.roo_batch import ROOBatch
from repro.data.jagged import JaggedTensor, KeyedJagged

import jax.numpy as jnp


@dataclasses.dataclass
class BatcherConfig:
    b_ro: int = 64                 # requests per batch
    b_nro: int = 512               # impression slots per batch
    hist_len: int = 64
    ro_idlist_capacity: int = 1024
    item_idlist_capacity: int = 4096
    n_shards: int = 1              # data shards; leading dims divisible by it
    local_segment_ids: bool = False
    label_keys: Sequence[str] = ("click", "view_sec")


def _pad2d(rows: List[np.ndarray], n: int, width: int, dtype=np.float32):
    out = np.zeros((n, width), dtype)
    for i, r in enumerate(rows[:n]):
        w = min(width, r.shape[-1])
        out[i, :w] = np.asarray(r).ravel()[:w]
    return out


def _pad_seq(rows: List[List[int]], n: int, width: int):
    out = np.zeros((n, width), np.int32)
    lens = np.zeros((n,), np.int32)
    for i, r in enumerate(rows[:n]):
        k = min(width, len(r))
        if k:
            out[i, :k] = np.asarray(r[-k:], np.int32)   # keep most recent
        lens[i] = k
    return out, lens


class ROOBatcher:
    """Greedy shard-aware packer: fills each shard's request/impression quota."""

    def __init__(self, cfg: BatcherConfig):
        assert cfg.b_ro % cfg.n_shards == 0 and cfg.b_nro % cfg.n_shards == 0
        self.cfg = cfg

    def batches(self, samples: Sequence[ROOSample]) -> Iterator[ROOBatch]:
        cfg = self.cfg
        per_shard_ro = cfg.b_ro // cfg.n_shards
        per_shard_nro = cfg.b_nro // cfg.n_shards
        queue = list(samples)
        while queue:
            shard_reqs: List[List[ROOSample]] = [[] for _ in range(cfg.n_shards)]
            shard_imps = [0] * cfg.n_shards
            progress = False
            for shard in range(cfg.n_shards):
                while queue and len(shard_reqs[shard]) < per_shard_ro:
                    s = queue[0]
                    n_imp = min(s.num_impressions, per_shard_nro)
                    if shard_imps[shard] + n_imp > per_shard_nro:
                        break
                    queue.pop(0)
                    shard_reqs[shard].append(s)
                    shard_imps[shard] += n_imp
                    progress = True
            if not progress:      # a single over-size request: truncate it
                s = queue.pop(0)
                s = dataclasses.replace(
                    s, item_ids=s.item_ids[:per_shard_nro],
                    item_dense=s.item_dense[:per_shard_nro],
                    item_idlist=s.item_idlist[:per_shard_nro],
                    labels=s.labels[:per_shard_nro])
                shard_reqs[0].append(s)
            yield self._pack(shard_reqs)

    def _pack(self, shard_reqs: List[List[ROOSample]]) -> ROOBatch:
        cfg = self.cfg
        per_shard_ro = cfg.b_ro // cfg.n_shards
        per_shard_nro = cfg.b_nro // cfg.n_shards

        ro_dense_rows, ro_idlists, hists, acts = [], [], [], []
        num_imp = np.zeros((cfg.b_ro,), np.int32)
        seg = np.full((cfg.b_nro,), cfg.b_ro, np.int32)
        nro_dense_rows: List[np.ndarray] = []
        nro_idlists: List[List[int]] = []
        item_ids = np.zeros((cfg.b_nro,), np.int32)
        labels = np.zeros((cfg.b_nro, len(cfg.label_keys)), np.float32)

        nro_fill = [0] * cfg.n_shards
        for shard, reqs in enumerate(shard_reqs):
            for j, s in enumerate(reqs):
                row = shard * per_shard_ro + j
                ro_dense_rows.append((row, s.ro_dense))
                ro_idlists.append((row, s.ro_idlist))
                hists.append((row, s.history_ids))
                acts.append((row, s.history_actions))
                n = min(s.num_impressions, per_shard_nro - nro_fill[shard])
                num_imp[row] = n
                for k in range(n):
                    slot = shard * per_shard_nro + nro_fill[shard]
                    nro_fill[shard] += 1
                    seg[slot] = (j if cfg.local_segment_ids else row)
                    item_ids[slot] = s.item_ids[k]
                    nro_dense_rows.append((slot, s.item_dense[k]))
                    nro_idlists.append((slot, s.item_idlist[k]))
                    labels[slot] = [s.labels[k].get(key, 0.0)
                                    for key in cfg.label_keys]
        if cfg.local_segment_ids:
            # padding marker becomes local b_ro
            pad = seg == cfg.b_ro
            seg = np.where(pad, per_shard_ro, seg)

        # densify RO side
        n_ro_dense = ro_dense_rows[0][1].shape[-1] if ro_dense_rows else 1
        ro_dense = np.zeros((cfg.b_ro, n_ro_dense), np.float32)
        for row, v in ro_dense_rows:
            ro_dense[row] = np.asarray(v, np.float32)[:n_ro_dense]
        hist_rows = [[] for _ in range(cfg.b_ro)]
        act_rows = [[] for _ in range(cfg.b_ro)]
        for row, h in hists:
            hist_rows[row] = list(h)
        for row, a in acts:
            act_rows[row] = list(a)
        history_ids, hist_lens = _pad_seq(hist_rows, cfg.b_ro, cfg.hist_len)
        history_actions, _ = _pad_seq(act_rows, cfg.b_ro, cfg.hist_len)

        ro_idlist_rows = [[] for _ in range(cfg.b_ro)]
        for row, ids in ro_idlists:
            ro_idlist_rows[row] = list(ids)
        ro_sparse = KeyedJagged({"user_ids": JaggedTensor.from_lists(
            ro_idlist_rows, cfg.ro_idlist_capacity)})

        n_item_dense = nro_dense_rows[0][1].shape[-1] if nro_dense_rows else 1
        nro_dense = np.zeros((cfg.b_nro, n_item_dense), np.float32)
        for slot, v in nro_dense_rows:
            nro_dense[slot] = np.asarray(v, np.float32)[:n_item_dense]
        nro_idlist_rows = [[] for _ in range(cfg.b_nro)]
        for slot, ids in nro_idlists:
            nro_idlist_rows[slot] = list(ids)
        nro_sparse = KeyedJagged({"item_cats": JaggedTensor.from_lists(
            nro_idlist_rows, cfg.item_idlist_capacity)})

        return ROOBatch(
            ro_dense=jnp.asarray(ro_dense),
            ro_sparse=ro_sparse,
            history_ids=jnp.asarray(history_ids),
            history_actions=jnp.asarray(history_actions),
            history_lengths=jnp.asarray(hist_lens),
            nro_dense=jnp.asarray(nro_dense),
            nro_sparse=nro_sparse,
            item_ids=jnp.asarray(item_ids),
            labels=jnp.asarray(labels),
            num_impressions=jnp.asarray(num_imp),
            segment_ids=jnp.asarray(seg),
        )


def impression_batches(samples: Sequence[ImpressionSample], batch_size: int,
                       cfg: BatcherConfig) -> Iterator[ROOBatch]:
    """Pack impression samples as degenerate ROO batches (1 impression per
    'request'): this is exactly impression-level training, reusing the same
    model code. B_RO == B_NRO == batch_size."""
    from repro.core.joiner import ROOSample as _RS
    roo_like = [
        _RS(request_id=s.request_id, user_id=s.user_id, ro_dense=s.ro_dense,
            ro_idlist=s.ro_idlist, history_ids=s.history_ids,
            history_actions=s.history_actions, item_ids=[s.item_id],
            item_dense=[s.item_dense], item_idlist=[s.item_idlist],
            labels=[s.labels])
        for s in samples
    ]
    sub = dataclasses.replace(cfg, b_ro=batch_size, b_nro=batch_size)
    yield from ROOBatcher(sub).batches(roo_like)
