"""ROO mini-batch packing (host side, numpy).

Packs a list of ROOSamples into fixed-shape ``ROOBatch`` pytrees:
  * ``B_RO`` request rows, ``B_NRO`` impression slots (static capacities);
  * requests are packed shard-by-shard so that, when the leading dims are
    sharded over N data shards, every request's impressions live on the same
    shard as the request row (the *request-locality* invariant fanout_local
    depends on);
  * ``segment_ids`` can be emitted global (default) or shard-local.

Packing metadata: ``batches_with_plan`` additionally yields a ``BatchPlan``
mapping every input request to its (row, slot range) in the packed batch —
the structure serving needs to return scores exactly aligned with each
request's ``item_ids`` — and counts impressions dropped by truncation so
training-data loss is observable instead of silent.

Also provides the impression-level packing used by baseline (non-ROO)
training and by the ROO-expansion backward-compat adapter.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Iterator, List, Sequence, Tuple

import numpy as np

from repro.core.joiner import ImpressionSample, ROOSample
from repro.core.roo_batch import ROOBatch
from repro.data.jagged import JaggedTensor, KeyedJagged
from repro.obs import metrics as obs_metrics

import jax.numpy as jnp


@dataclasses.dataclass
class BatcherConfig:
    b_ro: int = 64                 # requests per batch
    b_nro: int = 512               # impression slots per batch
    hist_len: int = 64
    ro_idlist_capacity: int = 1024
    item_idlist_capacity: int = 4096
    n_shards: int = 1              # data shards; leading dims divisible by it
    local_segment_ids: bool = False
    label_keys: Sequence[str] = ("click", "view_sec")


@dataclasses.dataclass(frozen=True)
class PackedRequest:
    """Where one input request landed inside a packed ROOBatch.

    A request's impressions always occupy *contiguous* NRO slots
    (``slot_start .. slot_start + n_packed``), so per-request scores are a
    plain slice of the batch-level score array.
    """
    request_index: int        # index into the samples passed to batches()
    row: int                  # RO row in the batch
    slot_start: int           # first NRO slot
    n_packed: int             # impressions packed into this batch
    n_total: int              # the request's total impressions

    @property
    def n_dropped(self) -> int:
        return self.n_total - self.n_packed


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Request -> slot mapping for one packed batch (same order as packing)."""
    requests: Tuple[PackedRequest, ...]

    @property
    def dropped_impressions(self) -> int:
        return sum(p.n_dropped for p in self.requests)

    @property
    def truncated_requests(self) -> int:
        return sum(1 for p in self.requests if p.n_dropped > 0)


@dataclasses.dataclass
class BatcherStats:
    """Accumulated over one ``batches``/``batches_with_plan`` call."""
    n_batches: int = 0
    n_requests: int = 0
    n_impressions_packed: int = 0
    n_impressions_dropped: int = 0
    n_requests_truncated: int = 0

    def update(self, plan: BatchPlan) -> None:
        self.n_batches += 1
        self.n_requests += len(plan.requests)
        self.n_impressions_packed += sum(p.n_packed for p in plan.requests)
        self.n_impressions_dropped += plan.dropped_impressions
        self.n_requests_truncated += plan.truncated_requests

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


def _pad2d(rows: List[np.ndarray], n: int, width: int, dtype=np.float32):
    out = np.zeros((n, width), dtype)
    for i, r in enumerate(rows[:n]):
        w = min(width, r.shape[-1])
        out[i, :w] = np.asarray(r).ravel()[:w]
    return out


def _pad_seq(rows: List[List[int]], n: int, width: int):
    out = np.zeros((n, width), np.int32)
    lens = np.zeros((n,), np.int32)
    for i, r in enumerate(rows[:n]):
        k = min(width, len(r))
        if k:
            out[i, :k] = np.asarray(r[-k:], np.int32)   # keep most recent
        lens[i] = k
    return out, lens


class ROOBatcher:
    """Greedy shard-aware packer: fills each shard's request/impression quota."""

    def __init__(self, cfg: BatcherConfig):
        assert cfg.b_ro % cfg.n_shards == 0 and cfg.b_nro % cfg.n_shards == 0
        self.cfg = cfg
        self.stats = BatcherStats()   # accumulated over the most recent call
        self._trunc_warned = False    # warn once per batcher, count the rest

    def batches(self, samples: Sequence[ROOSample]) -> Iterator[ROOBatch]:
        for batch, _ in self.batches_with_plan(samples):
            yield batch

    def batches_with_plan(
            self, samples: Sequence[ROOSample],
    ) -> Iterator[Tuple[ROOBatch, BatchPlan]]:
        """Yield (batch, plan); the plan maps every admitted request to its
        (row, slot range) and records impressions dropped by truncation."""
        cfg = self.cfg
        per_shard_ro = cfg.b_ro // cfg.n_shards
        per_shard_nro = cfg.b_nro // cfg.n_shards
        queue = list(enumerate(samples))
        self.stats = BatcherStats()
        while queue:
            # entries: (request_index, sample, n_total_impressions)
            shard_reqs: List[List[Tuple[int, ROOSample, int]]] = [
                [] for _ in range(cfg.n_shards)]
            shard_imps = [0] * cfg.n_shards
            for shard in range(cfg.n_shards):
                while queue and len(shard_reqs[shard]) < per_shard_ro:
                    idx, s = queue[0]
                    # clamped to the shard quota, so an over-size request is
                    # always admitted into an empty shard (and truncated by
                    # _pack, which the plan records)
                    n_imp = min(s.num_impressions, per_shard_nro)
                    if shard_imps[shard] + n_imp > per_shard_nro:
                        break
                    queue.pop(0)
                    shard_reqs[shard].append((idx, s, s.num_impressions))
                    shard_imps[shard] += n_imp
            batch, plan = self._pack(shard_reqs)
            self.stats.update(plan)
            if plan.dropped_impressions:
                # always counted (ungated: data loss must never be silent);
                # warned once per batcher so a long run that truncates on
                # every batch doesn't flood stderr
                obs_metrics.counter(
                    "batcher.impressions_dropped",
                    gated=False).inc(plan.dropped_impressions)
                if not self._trunc_warned:
                    self._trunc_warned = True
                    warnings.warn(
                        f"ROOBatcher: dropped {plan.dropped_impressions} "
                        f"impression(s) from {plan.truncated_requests} "
                        f"truncated request(s) — b_nro={cfg.b_nro} "
                        f"(per-shard {per_shard_nro}) is smaller than the "
                        f"request", stacklevel=2)
            yield batch, plan

    def _pack(self, shard_reqs: List[List[Tuple[int, ROOSample, int]]]
              ) -> Tuple[ROOBatch, BatchPlan]:
        cfg = self.cfg
        per_shard_ro = cfg.b_ro // cfg.n_shards
        per_shard_nro = cfg.b_nro // cfg.n_shards

        ro_dense_rows, ro_idlists, hists, acts = [], [], [], []
        num_imp = np.zeros((cfg.b_ro,), np.int32)
        seg = np.full((cfg.b_nro,), cfg.b_ro, np.int32)
        nro_dense_rows: List[np.ndarray] = []
        nro_idlists: List[List[int]] = []
        item_ids = np.zeros((cfg.b_nro,), np.int32)
        labels = np.zeros((cfg.b_nro, len(cfg.label_keys)), np.float32)

        nro_fill = [0] * cfg.n_shards
        packed: List[PackedRequest] = []
        for shard, reqs in enumerate(shard_reqs):
            for j, (idx, s, n_total) in enumerate(reqs):
                row = shard * per_shard_ro + j
                ro_dense_rows.append((row, s.ro_dense))
                ro_idlists.append((row, s.ro_idlist))
                hists.append((row, s.history_ids))
                acts.append((row, s.history_actions))
                n = min(s.num_impressions, per_shard_nro - nro_fill[shard])
                num_imp[row] = n
                packed.append(PackedRequest(
                    request_index=idx, row=row,
                    slot_start=shard * per_shard_nro + nro_fill[shard],
                    n_packed=n, n_total=n_total))
                for k in range(n):
                    slot = shard * per_shard_nro + nro_fill[shard]
                    nro_fill[shard] += 1
                    seg[slot] = (j if cfg.local_segment_ids else row)
                    item_ids[slot] = s.item_ids[k]
                    nro_dense_rows.append((slot, s.item_dense[k]))
                    nro_idlists.append((slot, s.item_idlist[k]))
                    labels[slot] = [s.labels[k].get(key, 0.0)
                                    for key in cfg.label_keys]
        if cfg.local_segment_ids:
            # padding marker becomes local b_ro
            pad = seg == cfg.b_ro
            seg = np.where(pad, per_shard_ro, seg)

        # densify RO side
        n_ro_dense = ro_dense_rows[0][1].shape[-1] if ro_dense_rows else 1
        ro_dense = np.zeros((cfg.b_ro, n_ro_dense), np.float32)
        for row, v in ro_dense_rows:
            ro_dense[row] = np.asarray(v, np.float32)[:n_ro_dense]
        hist_rows = [[] for _ in range(cfg.b_ro)]
        act_rows = [[] for _ in range(cfg.b_ro)]
        for row, h in hists:
            hist_rows[row] = list(h)
        for row, a in acts:
            act_rows[row] = list(a)
        history_ids, hist_lens = _pad_seq(hist_rows, cfg.b_ro, cfg.hist_len)
        history_actions, _ = _pad_seq(act_rows, cfg.b_ro, cfg.hist_len)

        ro_idlist_rows = [[] for _ in range(cfg.b_ro)]
        for row, ids in ro_idlists:
            ro_idlist_rows[row] = list(ids)
        ro_sparse = KeyedJagged({"user_ids": JaggedTensor.from_lists(
            ro_idlist_rows, cfg.ro_idlist_capacity)})

        n_item_dense = nro_dense_rows[0][1].shape[-1] if nro_dense_rows else 1
        nro_dense = np.zeros((cfg.b_nro, n_item_dense), np.float32)
        for slot, v in nro_dense_rows:
            nro_dense[slot] = np.asarray(v, np.float32)[:n_item_dense]
        nro_idlist_rows = [[] for _ in range(cfg.b_nro)]
        for slot, ids in nro_idlists:
            nro_idlist_rows[slot] = list(ids)
        nro_sparse = KeyedJagged({"item_cats": JaggedTensor.from_lists(
            nro_idlist_rows, cfg.item_idlist_capacity)})

        batch = ROOBatch(
            ro_dense=jnp.asarray(ro_dense),
            ro_sparse=ro_sparse,
            history_ids=jnp.asarray(history_ids),
            history_actions=jnp.asarray(history_actions),
            history_lengths=jnp.asarray(hist_lens),
            nro_dense=jnp.asarray(nro_dense),
            nro_sparse=nro_sparse,
            item_ids=jnp.asarray(item_ids),
            labels=jnp.asarray(labels),
            num_impressions=jnp.asarray(num_imp),
            segment_ids=jnp.asarray(seg),
        )
        return batch, BatchPlan(requests=tuple(packed))


def impression_batches(samples: Sequence[ImpressionSample], batch_size: int,
                       cfg: BatcherConfig) -> Iterator[ROOBatch]:
    """Pack impression samples as degenerate ROO batches (1 impression per
    'request'): this is exactly impression-level training, reusing the same
    model code. B_RO == B_NRO == batch_size."""
    from repro.core.joiner import ROOSample as _RS
    roo_like = [
        _RS(request_id=s.request_id, user_id=s.user_id, ro_dense=s.ro_dense,
            ro_idlist=s.ro_idlist, history_ids=s.history_ids,
            history_actions=s.history_actions, item_ids=[s.item_id],
            item_dense=[s.item_dense], item_idlist=[s.item_idlist],
            labels=[s.labels])
        for s in samples
    ]
    sub = dataclasses.replace(cfg, b_ro=batch_size, b_nro=batch_size)
    yield from ROOBatcher(sub).batches(roo_like)
