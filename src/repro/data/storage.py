"""Columnar storage codec: byte accounting AND a real encode/decode format.

Two layers live here:

1. **Byte accounting** (`encode_impression_table` / `encode_roo_table` /
   `sample_volume_increase`) — models the paper's feature-flattened columnar
   warm storage (§2.1.1, [45]) to reproduce Table 4's *ratio* claim.

2. **Shard codec** (`encode_roo_shard` / `decode_roo_shard` and the
   impression-level counterparts) — an actual on-disk columnar format used
   by the request-log pipeline (repro/pipeline/shards.py). One shard blob is

       magic "ROOSHRD1" | u32 header_len | header JSON | column blocks

   where each column block is ``u32 name_len | name | u8 dtype | u8 flags |
   u64 raw_len | u64 stored_len | u32 crc32 | payload`` (flags bit 0 =
   zlib; the ``crc32`` field — over the stored payload — is new in schema
   v2 and absent from v1 blocks, which remain readable: the header's
   ``schema_version`` tells the reader which frame it is). The header
   carries ``schema`` + ``schema_version`` so readers can reject formats
   they don't understand, plus the label-key order and dedup pool size.

   **Corruption detection**: v2 readers verify every block's CRC before
   touching the payload and raise :class:`ShardCorruptionError` (also
   raised for truncated frames and undecompressible payloads), which the
   pipeline layer (pipeline/shards.py) turns into per-shard quarantine
   instead of a training crash.

   RO payloads (ro_dense, ro_idlist, history) are stored **deduplicated**:
   a pool of unique payloads plus one ``ro_ref`` int per request. Within a
   request this is the paper's native ROO dedup; across requests it also
   collapses repeated payloads from the same user (the RecD-style win —
   consecutive requests with an unchanged history share one pool entry).

   The codec is float32/int64-typed: encoding casts dense features and
   labels to float32 and ids to int64; decode returns exactly those dtypes.
"""
from __future__ import annotations

import json
import struct
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.joiner import ImpressionSample, ROOSample

SCHEMA_VERSION = 2      # v2 = per-block CRC32; v1 frames remain readable
_MAGIC = b"ROOSHRD1"
_DTYPES = {0: np.int32, 1: np.int64, 2: np.float32}
_DTYPE_CODES = {np.dtype(np.int32): 0, np.dtype(np.int64): 1,
                np.dtype(np.float32): 2}


class ShardCorruptionError(ValueError):
    """A shard blob failed integrity checks (CRC mismatch, truncated frame,
    undecompressible payload). Lenient readers quarantine; strict raise."""


def _col_bytes(arrays: Sequence[np.ndarray], compress: bool) -> int:
    if not arrays:
        return 0
    flat = np.concatenate([np.asarray(a).ravel() for a in arrays])
    raw = flat.astype(np.float32).tobytes() if flat.dtype.kind == "f" \
        else flat.astype(np.int32).tobytes()
    # length prefixes for ragged reconstruction
    lens = np.asarray([np.asarray(a).size for a in arrays], np.int32).tobytes()
    blob = raw + lens
    return len(zlib.compress(blob, 6)) if compress else len(blob)


def encode_impression_table(samples: List[ImpressionSample],
                            compress: bool = True) -> Dict[str, int]:
    """Column-block byte sizes for an impression-level table (Table 1)."""
    cols = {
        "request_id": _col_bytes([np.asarray([s.request_id, s.user_id, s.item_id])
                                  for s in samples], compress),
        "labels": _col_bytes([np.asarray(list(s.labels.values()), np.float32)
                              for s in samples], compress),
        "ro_dense": _col_bytes([s.ro_dense for s in samples], compress),
        "ro_idlist": _col_bytes([np.asarray(s.ro_idlist, np.int32)
                                 for s in samples], compress),
        "history": _col_bytes([np.asarray(s.history_ids, np.int32)
                               for s in samples], compress)
                   + _col_bytes([np.asarray(s.history_actions, np.int32)
                                 for s in samples], compress),
        "item_dense": _col_bytes([s.item_dense for s in samples], compress),
        "item_idlist": _col_bytes([np.asarray(s.item_idlist, np.int32)
                                   for s in samples], compress),
    }
    cols["total"] = sum(v for k, v in cols.items() if k != "total")
    return cols


def encode_roo_table(samples: List[ROOSample],
                     compress: bool = True) -> Dict[str, int]:
    """Column-block byte sizes for a request-level table (Table 2)."""
    cols = {
        "request_id": _col_bytes([np.asarray([s.request_id, s.user_id])
                                  for s in samples], compress),
        "labels": _col_bytes([np.asarray([list(l.values()) for l in s.labels],
                                         np.float32) for s in samples], compress),
        "ro_dense": _col_bytes([s.ro_dense for s in samples], compress),
        "ro_idlist": _col_bytes([np.asarray(s.ro_idlist, np.int32)
                                 for s in samples], compress),
        "history": _col_bytes([np.asarray(s.history_ids, np.int32)
                               for s in samples], compress)
                   + _col_bytes([np.asarray(s.history_actions, np.int32)
                                 for s in samples], compress),
        "item_ids": _col_bytes([np.asarray(s.item_ids, np.int32)
                                for s in samples], compress),
        "item_dense": _col_bytes([np.concatenate([d.ravel() for d in s.item_dense])
                                  for s in samples], compress),
        "item_idlist": _col_bytes([np.concatenate(
            [np.asarray(l, np.int32).ravel() for l in s.item_idlist])
            for s in samples], compress),
    }
    cols["total"] = sum(v for k, v in cols.items() if k != "total")
    return cols


def sample_volume_increase(imp_samples: List[ImpressionSample],
                           roo_samples: List[ROOSample],
                           compress: bool = True) -> Dict[str, float]:
    """Paper Table 4: % more impressions storable in the same bytes.

    bytes/impression under each schema; increase = imp/roo - 1.
    """
    n_imp = len(imp_samples)
    n_roo_imp = sum(s.num_impressions for s in roo_samples)
    b_imp = encode_impression_table(imp_samples, compress)["total"]
    b_roo = encode_roo_table(roo_samples, compress)["total"]
    per_imp = b_imp / max(n_imp, 1)
    per_roo = b_roo / max(n_roo_imp, 1)
    return {
        "bytes_per_impression_impression_schema": per_imp,
        "bytes_per_impression_roo_schema": per_roo,
        "sample_volume_increase_pct": 100.0 * (per_imp / per_roo - 1.0),
    }


# ---------------------------------------------------------------------------
# Shard codec (real encode/decode; used by repro/pipeline/shards.py)
# ---------------------------------------------------------------------------

def _write_block(parts: List[bytes], name: str, arr: np.ndarray,
                 compress: bool, crc: bool = True) -> None:
    arr = np.ascontiguousarray(arr)
    code = _DTYPE_CODES[arr.dtype]
    raw = arr.tobytes()
    flags = 0
    payload = raw
    if compress:
        z = zlib.compress(raw, 6)
        if len(z) < len(raw):
            payload, flags = z, 1
    nm = name.encode("utf-8")
    parts.append(struct.pack("<I", len(nm)))
    parts.append(nm)
    parts.append(struct.pack("<BBQQ", code, flags, len(raw), len(payload)))
    if crc:
        parts.append(struct.pack("<I", zlib.crc32(payload)))
    parts.append(payload)


def _read_blocks(blob: bytes, offset: int,
                 crc: bool = True) -> Dict[str, np.ndarray]:
    cols: Dict[str, np.ndarray] = {}
    n = len(blob)
    try:
        while offset < n:
            (nm_len,) = struct.unpack_from("<I", blob, offset)
            offset += 4
            name = blob[offset:offset + nm_len].decode("utf-8")
            offset += nm_len
            code, flags, raw_len, stored_len = struct.unpack_from(
                "<BBQQ", blob, offset)
            offset += struct.calcsize("<BBQQ")
            want_crc = None
            if crc:
                (want_crc,) = struct.unpack_from("<I", blob, offset)
                offset += 4
            payload = blob[offset:offset + stored_len]
            offset += stored_len
            if len(payload) != stored_len:
                raise ShardCorruptionError(
                    f"shard column {name!r}: truncated payload")
            if want_crc is not None and zlib.crc32(payload) != want_crc:
                raise ShardCorruptionError(
                    f"shard column {name!r}: CRC32 mismatch (stored "
                    f"{want_crc:#010x}, computed {zlib.crc32(payload):#010x})")
            raw = zlib.decompress(payload) if flags & 1 else payload
            if len(raw) != raw_len:
                raise ShardCorruptionError(
                    f"shard column {name!r}: raw length mismatch")
            cols[name] = np.frombuffer(raw, dtype=_DTYPES[code]).copy()
    except (struct.error, UnicodeDecodeError, zlib.error, KeyError) as e:
        # truncated frame / garbage name / undecompressible payload / bad
        # dtype code — all shapes a bit-flip takes in a v1 (no-CRC) block
        raise ShardCorruptionError(f"shard frame unreadable: {e}") from e
    return cols


def _frame(header: Dict, parts: List[bytes]) -> bytes:
    hdr = json.dumps(header, sort_keys=True).encode("utf-8")
    return b"".join([_MAGIC, struct.pack("<I", len(hdr)), hdr] + parts)


def peek_shard_header(blob: bytes) -> Dict:
    """Parse just the header JSON (schema checks, manifest stats)."""
    if blob[:8] != _MAGIC:
        raise ShardCorruptionError("not a ROO shard (bad magic)")
    try:
        (hdr_len,) = struct.unpack_from("<I", blob, 8)
        return json.loads(blob[12:12 + hdr_len].decode("utf-8"))
    except (struct.error, UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ShardCorruptionError(f"shard header unreadable: {e}") from e


def _decode_body(blob: bytes) -> Tuple[Dict, Dict[str, np.ndarray]]:
    header = peek_shard_header(blob)
    (hdr_len,) = struct.unpack_from("<I", blob, 8)
    if header.get("schema_version", 0) > SCHEMA_VERSION:
        raise ValueError(
            f"shard schema_version {header['schema_version']} is newer than "
            f"supported {SCHEMA_VERSION}")
    # v1 blocks carry no CRC field; v2+ blocks are verified before use
    has_crc = header.get("schema_version", 0) >= 2
    return header, _read_blocks(blob, 12 + hdr_len, crc=has_crc)


def _ragged(values_by_row: Sequence[np.ndarray], dtype) -> Tuple[np.ndarray,
                                                                 np.ndarray]:
    lens = np.asarray([np.asarray(v).size for v in values_by_row], np.int32)
    if values_by_row:
        vals = np.concatenate(
            [np.asarray(v, dtype).ravel() for v in values_by_row]) \
            if lens.sum() else np.zeros((0,), dtype)
    else:
        vals = np.zeros((0,), dtype)
    return vals, lens


def _split_ragged(vals: np.ndarray, lens: np.ndarray) -> List[np.ndarray]:
    return np.split(vals, np.cumsum(lens)[:-1]) if lens.size else []


def _infer_label_keys(labels: Sequence[Dict[str, float]]) -> Tuple[str, ...]:
    for lab in labels:
        if lab:
            return tuple(lab.keys())
    return ()


class _Pool:
    """Dedup pool for one RO payload component: unique rows + per-row refs.

    A "row" is a tuple of parallel arrays (1 for ro_dense/ro_idlist, 2 for
    the history ids/acts pair); identity is the length-prefixed
    concatenation of the components, so ([1],[2,3]) and ([1,2],[3]) never
    collide.
    """

    def __init__(self):
        self.index: Dict[bytes, int] = {}
        self.rows: List[Tuple[np.ndarray, ...]] = []
        self.refs: List[int] = []

    def add(self, *row: np.ndarray) -> None:
        key = b"".join(struct.pack("<Q", a.nbytes) + a.tobytes()
                       for a in row)
        ref = self.index.get(key)
        if ref is None:
            ref = len(self.rows)
            self.index[key] = ref
            self.rows.append(row)
        self.refs.append(ref)

    def column(self, i: int) -> List[np.ndarray]:
        return [row[i] for row in self.rows]


def encode_roo_shard(samples: Sequence[ROOSample], compress: bool = True,
                     label_keys: Optional[Sequence[str]] = None,
                     crc: bool = True) -> bytes:
    """Serialize ROO samples into one columnar shard blob (schema v2).

    RO payloads are pooled **per component** (ro_dense / ro_idlist /
    history): identical rows are stored once, each request keeps int refs.
    Component-wise pooling is what pays off across requests — a user's
    ro_dense is stable and their history only changes on engagement, so
    consecutive requests share pool entries even when another component
    (e.g. a fast-moving id-list) differs.

    ``crc=False`` writes the legacy v1 frame (no per-block CRC32) — kept so
    the v1-compatibility path stays testable.
    """
    if label_keys is None:
        label_keys = _infer_label_keys(
            [l for s in samples for l in s.labels]) or ("click", "view_sec")
    label_keys = tuple(label_keys)

    dense_pool, idlist_pool, hist_pool = _Pool(), _Pool(), _Pool()
    for s in samples:
        dense_pool.add(np.asarray(s.ro_dense, np.float32).ravel())
        idlist_pool.add(np.asarray(s.ro_idlist, np.int64))
        hist_pool.add(np.asarray(s.history_ids, np.int64),
                      np.asarray(s.history_actions, np.int64))

    total_imp = sum(s.num_impressions for s in samples)
    labels = np.zeros((total_imp, max(len(label_keys), 1)), np.float32)
    row = 0
    item_dense_rows: List[np.ndarray] = []
    item_idlist_rows: List[np.ndarray] = []
    item_ids: List[int] = []
    for s in samples:
        for j in range(s.num_impressions):
            item_ids.append(int(s.item_ids[j]))
            item_dense_rows.append(np.asarray(s.item_dense[j], np.float32))
            item_idlist_rows.append(np.asarray(s.item_idlist[j], np.int64))
            for k, key in enumerate(label_keys):
                labels[row, k] = float(s.labels[j].get(key, 0.0))
            row += 1

    parts: List[bytes] = []

    def wb(name: str, arr: np.ndarray) -> None:
        _write_block(parts, name, arr, compress, crc=crc)

    wb("request_id", np.asarray([s.request_id for s in samples], np.int64))
    wb("user_id", np.asarray([s.user_id for s in samples], np.int64))
    wb("num_impressions",
       np.asarray([s.num_impressions for s in samples], np.int32))
    wb("ro_dense_ref", np.asarray(dense_pool.refs, np.int32))
    wb("ro_idlist_ref", np.asarray(idlist_pool.refs, np.int32))
    wb("history_ref", np.asarray(hist_pool.refs, np.int32))
    for name, rows, dtype in (
            ("pool_ro_dense", dense_pool.column(0), np.float32),
            ("pool_ro_idlist", idlist_pool.column(0), np.int64),
            ("pool_hist_ids", hist_pool.column(0), np.int64),
            ("pool_hist_acts", hist_pool.column(1), np.int64),
            ("item_dense", item_dense_rows, np.float32),
            ("item_idlist", item_idlist_rows, np.int64)):
        vals, lens = _ragged(rows, dtype)
        wb(name + "_vals", vals)
        wb(name + "_lens", lens)
    wb("item_ids", np.asarray(item_ids, np.int64))
    wb("labels", labels.ravel())

    pool_sizes = {"ro_dense": len(dense_pool.rows),
                  "ro_idlist": len(idlist_pool.rows),
                  "history": len(hist_pool.rows)}
    header = {
        "schema": "roo", "schema_version": SCHEMA_VERSION if crc else 1,
        "n_requests": len(samples), "n_impressions": total_imp,
        "pool_sizes": pool_sizes,
        "ro_pool_size": sum(pool_sizes.values()),
        "label_keys": list(label_keys),
        "compress": bool(compress),
    }
    return _frame(header, parts)


def decode_roo_shard(blob: bytes) -> List[ROOSample]:
    """Inverse of :func:`encode_roo_shard` (exact at codec dtypes)."""
    header, cols = _decode_body(blob)
    if header.get("schema") != "roo":
        raise ValueError(f"expected roo shard, got {header.get('schema')!r}")
    label_keys = tuple(header["label_keys"])
    n = header["n_requests"]

    pools = {}
    for name in ("pool_ro_dense", "pool_ro_idlist", "pool_hist_ids",
                 "pool_hist_acts", "item_dense", "item_idlist"):
        pools[name] = _split_ragged(cols[name + "_vals"],
                                    cols[name + "_lens"])
    num_imp = cols["num_impressions"]
    labels = cols["labels"].reshape(-1, max(len(label_keys), 1))
    item_ids = cols["item_ids"]
    imp_offsets = np.concatenate([[0], np.cumsum(num_imp)])

    out: List[ROOSample] = []
    for i in range(n):
        dref = int(cols["ro_dense_ref"][i])
        iref = int(cols["ro_idlist_ref"][i])
        href = int(cols["history_ref"][i])
        lo, hi = int(imp_offsets[i]), int(imp_offsets[i + 1])
        out.append(ROOSample(
            request_id=int(cols["request_id"][i]),
            user_id=int(cols["user_id"][i]),
            ro_dense=pools["pool_ro_dense"][dref].astype(np.float32),
            ro_idlist=[int(x) for x in pools["pool_ro_idlist"][iref]],
            history_ids=[int(x) for x in pools["pool_hist_ids"][href]],
            history_actions=[int(x) for x in pools["pool_hist_acts"][href]],
            item_ids=[int(x) for x in item_ids[lo:hi]],
            item_dense=[pools["item_dense"][j].astype(np.float32)
                        for j in range(lo, hi)],
            item_idlist=[[int(x) for x in pools["item_idlist"][j]]
                         for j in range(lo, hi)],
            labels=[{k: float(labels[j, c])
                     for c, k in enumerate(label_keys)}
                    for j in range(lo, hi)]))
    return out


def encode_impression_shard(samples: Sequence[ImpressionSample],
                            compress: bool = True,
                            label_keys: Optional[Sequence[str]] = None,
                            crc: bool = True) -> bytes:
    """Impression-level (Table 1) shard: RO features duplicated per row.

    This is the established-practice baseline the pipeline benchmark
    compares real on-disk bytes against; no dedup pool on purpose.
    """
    if label_keys is None:
        label_keys = _infer_label_keys([s.labels for s in samples]) \
            or ("click", "view_sec")
    label_keys = tuple(label_keys)
    n = len(samples)
    labels = np.zeros((n, max(len(label_keys), 1)), np.float32)
    for i, s in enumerate(samples):
        for k, key in enumerate(label_keys):
            labels[i, k] = float(s.labels.get(key, 0.0))

    parts: List[bytes] = []

    def wb(name: str, arr: np.ndarray) -> None:
        _write_block(parts, name, arr, compress, crc=crc)

    wb("request_id", np.asarray([s.request_id for s in samples], np.int64))
    wb("user_id", np.asarray([s.user_id for s in samples], np.int64))
    wb("item_id", np.asarray([s.item_id for s in samples], np.int64))
    for name, rows, dtype in (
            ("ro_dense", [s.ro_dense for s in samples], np.float32),
            ("ro_idlist", [np.asarray(s.ro_idlist, np.int64)
                           for s in samples], np.int64),
            ("hist_ids", [np.asarray(s.history_ids, np.int64)
                          for s in samples], np.int64),
            ("hist_acts", [np.asarray(s.history_actions, np.int64)
                           for s in samples], np.int64),
            ("item_dense", [s.item_dense for s in samples], np.float32),
            ("item_idlist", [np.asarray(s.item_idlist, np.int64)
                             for s in samples], np.int64)):
        vals, lens = _ragged(rows, dtype)
        wb(name + "_vals", vals)
        wb(name + "_lens", lens)
    wb("labels", labels.ravel())

    header = {
        "schema": "impression",
        "schema_version": SCHEMA_VERSION if crc else 1,
        "n_rows": n, "label_keys": list(label_keys),
        "compress": bool(compress),
    }
    return _frame(header, parts)


def decode_impression_shard(blob: bytes) -> List[ImpressionSample]:
    header, cols = _decode_body(blob)
    if header.get("schema") != "impression":
        raise ValueError(
            f"expected impression shard, got {header.get('schema')!r}")
    label_keys = tuple(header["label_keys"])
    n = header["n_rows"]
    labels = cols["labels"].reshape(-1, max(len(label_keys), 1))
    ragged = {name: _split_ragged(cols[name + "_vals"], cols[name + "_lens"])
              for name in ("ro_dense", "ro_idlist", "hist_ids", "hist_acts",
                           "item_dense", "item_idlist")}
    out: List[ImpressionSample] = []
    for i in range(n):
        out.append(ImpressionSample(
            request_id=int(cols["request_id"][i]),
            user_id=int(cols["user_id"][i]),
            item_id=int(cols["item_id"][i]),
            labels={k: float(labels[i, c])
                    for c, k in enumerate(label_keys)},
            ro_dense=ragged["ro_dense"][i].astype(np.float32),
            ro_idlist=[int(x) for x in ragged["ro_idlist"][i]],
            history_ids=[int(x) for x in ragged["hist_ids"][i]],
            history_actions=[int(x) for x in ragged["hist_acts"][i]],
            item_dense=ragged["item_dense"][i].astype(np.float32),
            item_idlist=[int(x) for x in ragged["item_idlist"][i]]))
    return out
