"""Columnar storage codec with byte accounting.

Models the paper's feature-flattened columnar warm storage (§2.1.1, [45]):
each feature is serialized as a column block (optionally zlib-compressed, as
columnar stores do). The benchmark question reproduced here is Table 4:
*how many impressions' worth of training data fit in the same storage* under
impression-level vs request-level (ROO) schemas.

This is deliberately simple — the paper's claim is about *ratios* driven by
RO-feature duplication, and ratios are what the codec measures.
"""
from __future__ import annotations

import struct
import zlib
from typing import Dict, List, Sequence

import numpy as np

from repro.core.joiner import ImpressionSample, ROOSample


def _col_bytes(arrays: Sequence[np.ndarray], compress: bool) -> int:
    if not arrays:
        return 0
    flat = np.concatenate([np.asarray(a).ravel() for a in arrays])
    raw = flat.astype(np.float32).tobytes() if flat.dtype.kind == "f" \
        else flat.astype(np.int32).tobytes()
    # length prefixes for ragged reconstruction
    lens = np.asarray([np.asarray(a).size for a in arrays], np.int32).tobytes()
    blob = raw + lens
    return len(zlib.compress(blob, 6)) if compress else len(blob)


def encode_impression_table(samples: List[ImpressionSample],
                            compress: bool = True) -> Dict[str, int]:
    """Column-block byte sizes for an impression-level table (Table 1)."""
    cols = {
        "request_id": _col_bytes([np.asarray([s.request_id, s.user_id, s.item_id])
                                  for s in samples], compress),
        "labels": _col_bytes([np.asarray(list(s.labels.values()), np.float32)
                              for s in samples], compress),
        "ro_dense": _col_bytes([s.ro_dense for s in samples], compress),
        "ro_idlist": _col_bytes([np.asarray(s.ro_idlist, np.int32)
                                 for s in samples], compress),
        "history": _col_bytes([np.asarray(s.history_ids, np.int32)
                               for s in samples], compress)
                   + _col_bytes([np.asarray(s.history_actions, np.int32)
                                 for s in samples], compress),
        "item_dense": _col_bytes([s.item_dense for s in samples], compress),
        "item_idlist": _col_bytes([np.asarray(s.item_idlist, np.int32)
                                   for s in samples], compress),
    }
    cols["total"] = sum(v for k, v in cols.items() if k != "total")
    return cols


def encode_roo_table(samples: List[ROOSample],
                     compress: bool = True) -> Dict[str, int]:
    """Column-block byte sizes for a request-level table (Table 2)."""
    cols = {
        "request_id": _col_bytes([np.asarray([s.request_id, s.user_id])
                                  for s in samples], compress),
        "labels": _col_bytes([np.asarray([list(l.values()) for l in s.labels],
                                         np.float32) for s in samples], compress),
        "ro_dense": _col_bytes([s.ro_dense for s in samples], compress),
        "ro_idlist": _col_bytes([np.asarray(s.ro_idlist, np.int32)
                                 for s in samples], compress),
        "history": _col_bytes([np.asarray(s.history_ids, np.int32)
                               for s in samples], compress)
                   + _col_bytes([np.asarray(s.history_actions, np.int32)
                                 for s in samples], compress),
        "item_ids": _col_bytes([np.asarray(s.item_ids, np.int32)
                                for s in samples], compress),
        "item_dense": _col_bytes([np.concatenate([d.ravel() for d in s.item_dense])
                                  for s in samples], compress),
        "item_idlist": _col_bytes([np.concatenate(
            [np.asarray(l, np.int32).ravel() for l in s.item_idlist])
            for s in samples], compress),
    }
    cols["total"] = sum(v for k, v in cols.items() if k != "total")
    return cols


def sample_volume_increase(imp_samples: List[ImpressionSample],
                           roo_samples: List[ROOSample],
                           compress: bool = True) -> Dict[str, float]:
    """Paper Table 4: % more impressions storable in the same bytes.

    bytes/impression under each schema; increase = imp/roo - 1.
    """
    n_imp = len(imp_samples)
    n_roo_imp = sum(s.num_impressions for s in roo_samples)
    b_imp = encode_impression_table(imp_samples, compress)["total"]
    b_roo = encode_roo_table(roo_samples, compress)["total"]
    per_imp = b_imp / max(n_imp, 1)
    per_roo = b_roo / max(n_roo_imp, 1)
    return {
        "bytes_per_impression_impression_schema": per_imp,
        "bytes_per_impression_roo_schema": per_roo,
        "sample_volume_increase_pct": 100.0 * (per_imp / per_roo - 1.0),
    }
