"""Synthetic impression/conversion event streams.

Reproduces the *structure* of the paper's data (Fig. 1a / Fig. 2): users
issue requests; each request serves several impressions; feedback events
(conversions, view durations) arrive with delay during the feedback phase.

Labels are planted from a ground-truth logit model
``p(click) = sigmoid(<u*, i*> / sqrt(d) + b)`` over latent user/item vectors,
so downstream NE / Recall@K deltas between models are meaningful rather than
noise.

Impressions-per-request distributions mimic the paper's three products
(Fig. 2 — means in the 4–7 range, heavy tail):
  product_a: mean ~4.2   product_b: mean ~6.8   product_c: mean ~5.4
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List

import numpy as np


@dataclasses.dataclass
class ImpressionEvent:
    ts: float
    user_id: int
    request_id: int
    item_id: int
    # item-side (NRO) payload
    item_dense: np.ndarray            # (n_item_dense,)
    item_idlist: List[int]            # item id-list feature (e.g. categories)
    # user-side (RO) payload — identical for every impression of the request;
    # impression-level logging stores it per event (this is the waste ROO removes)
    ro_dense: np.ndarray              # (n_ro_dense,)
    ro_idlist: List[int]              # e.g. user engaged-category ids
    history_ids: List[int]            # user history item ids
    history_actions: List[int]


@dataclasses.dataclass
class ConversionEvent:
    ts: float
    user_id: int
    request_id: int
    item_id: int
    labels: Dict[str, float]          # {"click":0/1, "view_sec": float}


PRODUCT_MIX = {
    # (geometric-ish pmf support 1..16, mean):
    "product_a": 4.2,
    "product_b": 6.8,
    "product_c": 5.4,
}


@dataclasses.dataclass
class EventStreamConfig:
    n_users: int = 200
    n_items: int = 5000
    n_requests: int = 1000
    product: str = "product_a"
    n_ro_dense: int = 16
    n_item_dense: int = 8
    hist_len_max: int = 64
    ro_idlist_max: int = 12
    item_idlist_max: int = 4
    latent_dim: int = 16
    feedback_delay_mean_s: float = 240.0   # conversions trail impressions
    # late-conversion tail: with probability ``late_fraction`` a conversion's
    # delay gets an extra exponential(late_delay_mean_s) draw — the heavy
    # tail that makes joiner watermark/label-wait behavior testable
    # (benchmarks/join_quality.py sweeps it). When 0.0 (default) NO extra
    # rng draws happen, so existing seeds produce bit-identical streams.
    late_fraction: float = 0.0
    late_delay_mean_s: float = 3600.0
    request_gap_s: float = 30.0
    hist_init_max: int = 0     # seed users with random-length prior histories
    item_zipf: float = 0.0     # >0: Zipf-like item popularity (hot heads)
    seed: int = 0


class EventSimulator:
    """Generates a time-ordered interleaved stream of impression and
    conversion events, tracking per-user history so RO features evolve."""

    def __init__(self, cfg: EventStreamConfig):
        self.cfg = cfg
        self.rng = np.random.RandomState(cfg.seed)
        d = cfg.latent_dim
        self.user_latent = self.rng.normal(size=(cfg.n_users, d)) / np.sqrt(d)
        self.item_latent = self.rng.normal(size=(cfg.n_items, d)) / np.sqrt(d)
        self.item_cats = self.rng.randint(1, 200, size=(cfg.n_items, cfg.item_idlist_max))
        self.user_hist: Dict[int, List[int]] = {}
        self.user_acts: Dict[int, List[int]] = {}
        for u in range(cfg.n_users):
            n0 = int(self.rng.randint(0, cfg.hist_init_max + 1))
            self.user_hist[u] = self.rng.randint(0, cfg.n_items, size=n0).tolist()
            self.user_acts[u] = self.rng.randint(0, 2, size=n0).tolist()

    def _n_impressions(self) -> int:
        mean = PRODUCT_MIX[self.cfg.product]
        # zero-truncated geometric-ish with the product's mean; cap at 16
        p = 1.0 / mean
        n = 1 + self.rng.geometric(p) - 1
        return int(np.clip(n, 1, 16))

    def _ro_payload(self, user_id: int):
        cfg = self.cfg
        u = self.user_latent[user_id]
        ro_dense = np.concatenate([
            u[: cfg.n_ro_dense] if cfg.n_ro_dense <= u.shape[0] else
            np.resize(u, cfg.n_ro_dense)
        ]).astype(np.float32)
        hist = self.user_hist[user_id][-cfg.hist_len_max:]
        acts = self.user_acts[user_id][-cfg.hist_len_max:]
        ro_idlist = list(
            (np.abs(self.rng.randint(1, 200, size=self.rng.randint(1, self.cfg.ro_idlist_max + 1)))).tolist()
        )
        return ro_dense, ro_idlist, list(hist), list(acts)

    def stream(self) -> Iterator[object]:
        """Yield events in ts order (heap-merge of impressions + feedback)."""
        cfg = self.cfg
        pending: List[object] = []
        ts = 0.0
        for req in range(cfg.n_requests):
            ts += self.rng.exponential(cfg.request_gap_s)
            user = int(self.rng.randint(cfg.n_users))
            n_imp = self._n_impressions()
            if cfg.item_zipf > 0:
                # Zipf-ish popularity: u^(1/(1-a)) rank sampling, hot head
                u = self.rng.rand(n_imp * 2)
                ranks = (u ** (1.0 / (1.0 - cfg.item_zipf))
                         * cfg.n_items).astype(np.int64) % cfg.n_items
                items = np.unique(ranks)[:n_imp]
                while items.shape[0] < n_imp:   # top-up on collision
                    extra = int(self.rng.rand() ** (1.0 / (1.0 - cfg.item_zipf))
                                * cfg.n_items) % cfg.n_items
                    if extra not in items:
                        items = np.append(items, extra)
            else:
                items = self.rng.choice(cfg.n_items, size=n_imp, replace=False)
            ro_dense, ro_idlist, hist, acts = self._ro_payload(user)
            for item in items:
                item = int(item)
                item_dense = np.resize(self.item_latent[item], cfg.n_item_dense).astype(np.float32)
                pending.append(ImpressionEvent(
                    ts=ts, user_id=user, request_id=req, item_id=item,
                    item_dense=item_dense,
                    item_idlist=self.item_cats[item].tolist(),
                    ro_dense=ro_dense, ro_idlist=ro_idlist,
                    history_ids=hist, history_actions=acts))
                # planted label model
                logit = float(self.user_latent[user] @ self.item_latent[item]) * 4.0 - 1.0
                click = int(self.rng.rand() < 1.0 / (1.0 + np.exp(-logit)))
                view = float(np.exp(self.rng.normal(2.0, 0.5))) if click else 0.0
                delay = self.rng.exponential(cfg.feedback_delay_mean_s)
                if cfg.late_fraction > 0.0 \
                        and self.rng.rand() < cfg.late_fraction:
                    delay += self.rng.exponential(cfg.late_delay_mean_s)
                pending.append(ConversionEvent(
                    ts=ts + delay, user_id=user, request_id=req, item_id=item,
                    labels={"click": float(click), "view_sec": view}))
                # evolve history with positive engagements
                if click:
                    self.user_hist[user].append(item)
                    self.user_acts[user].append(1)
                elif self.rng.rand() < 0.3:
                    self.user_hist[user].append(item)
                    self.user_acts[user].append(0)
        pending.sort(key=lambda e: e.ts)
        yield from pending
