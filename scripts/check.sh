#!/usr/bin/env bash
# Tier-1 gate: run before sending a PR.
#   scripts/check.sh            — full test suite + kernel smoke benchmark
#   scripts/check.sh -k kernel  — extra args are forwarded to pytest
#
# The smoke benchmark exercises the HSTU attention dispatch backends
# (fwd + bwd) so perf/correctness regressions in the kernel path are
# caught locally even when only unit tests were touched; compare.py then
# gates the result against the committed baseline (>20% per-row slowdown
# after machine normalization fails the run).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

# lint locally when ruff is around; CI lints in its own named step first
if [[ -z "${CI:-}" ]] && command -v ruff >/dev/null 2>&1; then
  echo "== ruff lint =="
  ruff check src tests benchmarks
fi

echo "== tier-1 tests =="
python -m pytest -x -q "$@"

echo "== kernel/serving/pipeline smoke benchmark =="
python benchmarks/run.py --smoke --json bench_smoke.json

echo "== perf regression gate =="
python benchmarks/compare.py benchmarks/baseline_smoke.json bench_smoke.json
