#!/usr/bin/env bash
# Tier-1 gate: run before sending a PR.
#   scripts/check.sh            — full test suite + kernel smoke benchmark
#   scripts/check.sh -k kernel  — extra args are forwarded to pytest
#
# The smoke benchmark exercises the HSTU attention dispatch backends
# (fwd + bwd) so perf/correctness regressions in the kernel path are
# caught locally even when only unit tests were touched.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q "$@"

echo "== kernel/serving/pipeline smoke benchmark =="
python benchmarks/run.py --smoke --json bench_smoke.json
