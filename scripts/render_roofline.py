"""Render the EXPERIMENTS.md roofline + dry-run tables from artifacts."""
import glob
import json
import os
import sys

ART = "artifacts/dryrun"


def rows(mesh):
    out = []
    for f in sorted(glob.glob(f"{ART}/*__{mesh}.json")):
        d = json.load(open(f))
        if d.get("opt_level", "baseline") != "baseline":
            continue
        out.append(d)
    return out


def fmt(v, digits=3):
    if v == 0:
        return "0"
    if v < 1e-3 or v >= 1e4:
        return f"{v:.2e}"
    return f"{v:.{digits}g}"


def main():
    print("### Single-pod (16x16 = 256 chips) roofline — all 40 cells\n")
    print("| arch | shape | kind | compute_s | memory_s | collective_s | "
          "dominant | MODEL/HLO | bottleneck note |")
    print("|---|---|---|---|---|---|---|---|---|")
    notes = {
        "memory": "activation/param streaming",
        "collective": "cross-chip bytes",
        "compute": "MXU-bound",
    }
    for d in rows("pod1"):
        r = d["roofline"]
        ur = d.get("useful_flops_ratio")
        print(f"| {d['arch']} | {d['shape']} | {d['kind']} | "
              f"{fmt(r['compute_s'])} | {fmt(r['memory_s'])} | "
              f"{fmt(r['collective_s'])} | {r['dominant']} | "
              f"{ur:.2f} | {notes[r['dominant']]} |")
    print("\n### Multi-pod (2x16x16 = 512 chips) dry-run — all 40 cells\n")
    print("| arch | shape | compile | peak GB/dev | collective B/dev | ok |")
    print("|---|---|---|---|---|---|")
    for d in rows("pod2"):
        pk = d["memory_analysis"]["peak_bytes"] or 0
        print(f"| {d['arch']} | {d['shape']} | {d['compile_s']:.1f}s | "
              f"{pk / 1e9:.2f} | {fmt(d['collective_bytes'])} | yes |")


if __name__ == "__main__":
    main()
